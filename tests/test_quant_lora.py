"""Quantization + LoRA substrate tests (paper §3.3.1 / §3.3.5 executable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (quantize_weight, dequantize_weight, quant_dense,
                         quantize_tree, QuantizedTensor)
from repro.lora import (init_adapter, init_adapters_for_tree, merge,
                        apply_inline, merge_flops)
from repro.core import StatsDB
from repro.core import operators as F

RNG = np.random.default_rng(11)


def test_quant_dense_matches_dequant_matmul():
    x = jnp.asarray(RNG.standard_normal((16, 256)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((256, 128)) * 0.05, jnp.float32)
    q = quantize_weight(w, group_size=128, bits=4)
    via_kernel = quant_dense(x, q, use_kernel=True)
    via_dequant = quant_dense(x, q, use_kernel=False)
    np.testing.assert_allclose(np.asarray(via_kernel, np.float32),
                               np.asarray(via_dequant, np.float32),
                               atol=1e-3, rtol=1e-3)
    # quantization error bounded: int4 over a 256-deep contraction of
    # random gaussians lands around 10% output norm (√k error growth vs
    # √k signal cancellation) — bound at 15%
    exact = x @ w
    rel = float(jnp.linalg.norm(via_dequant - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.15


def test_int8_tighter_than_int4():
    w = jnp.asarray(RNG.standard_normal((512, 64)), jnp.float32)
    e4 = float(jnp.abs(dequantize_weight(quantize_weight(w, bits=4),
                                         jnp.float32) - w).max())
    e8 = float(jnp.abs(dequantize_weight(quantize_weight(w, bits=8),
                                         jnp.float32) - w).max())
    assert e8 < e4


def test_quantize_tree_storage_matches_life_model():
    """Real quantized storage bytes == LIFE's analytical storage bytes."""
    k, n, g = 4096, 4096, 128
    w = jnp.asarray(RNG.standard_normal((k, n)), jnp.bfloat16)
    tree = quantize_tree({"w": w, "norm": jnp.ones((k,), jnp.bfloat16)},
                         group_size=g, bits=4)
    assert isinstance(tree["w"], QuantizedTensor)
    real = tree["w"].storage_bytes()
    from repro.core import dtypes
    analytical = dtypes.get("int4").storage_bytes(k * n, g)
    assert real == pytest.approx(analytical, rel=0.01)


def test_lora_merge_equals_inline():
    x = jnp.asarray(RNG.standard_normal((8, 128)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((128, 64)) * 0.1, jnp.float32)
    ad = init_adapter(jax.random.PRNGKey(0), 128, 64, rank=8,
                      dtype=jnp.float32)
    # randomize B so the adapter is non-trivial
    ad["B"] = jax.random.normal(jax.random.PRNGKey(1), (8, 64),
                                jnp.float32) * 0.1
    merged = merge({"w": w}, {"w": ad})["w"]
    np.testing.assert_allclose(np.asarray(x @ merged),
                               np.asarray(apply_inline(x, w, ad)),
                               atol=1e-4)


def test_fresh_adapter_is_identity():
    x = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 32)), jnp.float32)
    ad = init_adapter(jax.random.PRNGKey(0), 64, 32, rank=4, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(apply_inline(x, w, ad)),
                               np.asarray(x @ w), atol=1e-5)


def test_merge_flops_matches_life_operator():
    db = StatsDB()
    F.lora_merge(db, 4096, 11008, 64)
    assert db.records[0].ops == merge_flops(4096, 11008, 64)


def test_adapters_for_tree_skips_small():
    tree = {"big": jnp.ones((512, 512)), "small": jnp.ones((4, 4)),
            "vec": jnp.ones((512,))}
    ads = init_adapters_for_tree(jax.random.PRNGKey(0), tree, rank=4)
    assert ads["big"] is not None
    assert ads["small"] is None and ads["vec"] is None


def test_adapters_for_tree_compute_dtype_not_storage_dtype():
    """Regression: adapters must land in the compute dtype, not inherit a
    quantized/low-precision base weight's storage dtype (an int8 base
    weight used to produce int8 A/B factors, which the low-rank GEMMs
    can't meaningfully run in)."""
    tree = {"w8": jnp.ones((512, 512), jnp.int8),
            "wb": jnp.ones((512, 512), jnp.bfloat16)}
    ads = init_adapters_for_tree(jax.random.PRNGKey(0), tree, rank=4)
    assert ads["w8"]["A"].dtype == jnp.bfloat16
    assert ads["w8"]["B"].dtype == jnp.bfloat16
    assert ads["wb"]["A"].dtype == jnp.bfloat16
    # explicit override still honored
    ads32 = init_adapters_for_tree(jax.random.PRNGKey(0), tree, rank=4,
                                   dtype=jnp.float32)
    assert ads32["w8"]["A"].dtype == jnp.float32
