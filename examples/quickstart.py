"""Quickstart: LIFE in 40 lines.

Characterize an LLM inference workload analytically (no weights, no data,
no accelerator) and forecast TTFT/TPOT/TPS on several hardware targets —
the paper's core loop (Fig. 2).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get, PAPER_VARIANTS
from repro.core import WorkloadModel, Forecaster, hardware

# 1. pick a model + optimization variant (paper Table 3)
arch = get("llama2-7b")
variant = PAPER_VARIANTS["bf16-int4-kv4"]       # int4 weights, int4 KV, fused
wm = WorkloadModel(arch, variant)

# 2. characterize: prefill a 2048-token prompt, then one decode step
prefill = wm.prefill(batch=1, seq=2048)
decode = wm.decode_step(batch=1, past_len=2048)

t = prefill.totals("prefill")
print(f"prefill 2048: {t.ops/1e12:.2f} TOPs, "
      f"{t.mem_rd/1e9:.1f} GB read, {t.kv_wr/1e9:.2f} GB KV written, "
      f"{t.dispatches} dispatches")
d = decode.totals("decode")
print(f"decode @2048: {d.ops/1e9:.2f} GOPs, {d.mem_total/1e9:.2f} GB touched")

# 3. forecast on real hardware — only TOPS + bandwidth needed (Eqs. 1-6)
for hw in (hardware.RYZEN_9_HX370_CPU, hardware.NVIDIA_V100,
           hardware.TPU_V5E):
    fc = Forecaster(hw)
    ttft = fc.ttft(prefill)
    tps = fc.tps(decode, em=0.8)
    print(f"{hw.name:22s} TTFT={ttft.latency*1e3:9.1f} ms "
          f"({ttft.bound}-bound)   TPS={tps:8.1f} @ em=0.8")

# 4. what would KV-cache compression buy on this device? (paper §3.3.3)
base = WorkloadModel(arch, PAPER_VARIANTS["bf16-int4"])
fc = Forecaster(hardware.TPU_V5E)
tps_base = fc.tps(base.decode_step(1, 8192), em=0.8)
tps_kv4 = fc.tps(wm.decode_step(1, 8192), em=0.8)
print(f"\nKV4 compression at 8k context: {tps_base:.0f} -> {tps_kv4:.0f} "
      f"tok/s ({tps_kv4/tps_base:.2f}x)")
