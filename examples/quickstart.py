"""Quickstart: LIFE in 30 lines via the unified Scenario→Report API.

Declare an inference workload once, forecast TTFT/TPOT/TPS on any
hardware (no weights, no data, no accelerator) — the paper's core loop
(Fig. 2) as three calls: Scenario → forecast → Report.

    PYTHONPATH=src python examples/quickstart.py

(The same pipeline is a CLI: ``python -m repro forecast --model llama2-7b
--variant bf16-int4-kv4 --hw tpu-v5e --prompt 2048 --gen 256``.)
"""
import dataclasses

from repro import api

# 1. declare the workload: model × optimization variant × traffic
scn = api.Scenario(model="llama2-7b", variant="bf16-int4-kv4",
                   prompt_len=2048, gen_len=256)

# 2. characterize: the Report carries the analytical workload per phase
r = api.forecast(scn, "tpu-v5e", em=0.8)
pre, dec = r.phases["prefill"], r.phases["decode"]
print(f"prefill 2048: {pre.ops/1e12:.2f} TOPs, {pre.mem_rd/1e9:.1f} GB read, "
      f"{pre.kv_wr/1e9:.2f} GB KV written, {pre.dispatches} dispatches")
print(f"decode @2048: {dec.ops/1e9:.2f} GOPs, {dec.mem_total/1e9:.2f} GB touched")

# 3. forecast across hardware — only TOPS + bandwidth needed (Eqs. 1-6)
for r in api.sweep(scn, ["cpu", "nvidia-v100", "v5e"], em=0.8):
    print(f"{r.hardware:22s} TTFT={r.ttft_s*1e3:9.1f} ms "
          f"({r.ttft_bound}-bound)   TPS={r.tps:8.1f} @ em=0.8")

# 4. what would KV-cache compression buy at 8k context? (paper §3.3.3)
long_ctx = dataclasses.replace(scn, past_lens=(8192,))
tps_base = api.forecast(dataclasses.replace(long_ctx, variant="bf16-int4"),
                        "tpu-v5e", em=0.8).tps
tps_kv4 = api.forecast(long_ctx, "tpu-v5e", em=0.8).tps
print(f"\nKV4 compression at 8k context: {tps_base:.0f} -> {tps_kv4:.0f} "
      f"tok/s ({tps_kv4/tps_base:.2f}x)")
