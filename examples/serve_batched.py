"""Serve a stream of requests through the continuous-batching engine —
the paper's optimization menu live, via the Scenario→Report API: chunked-
prefill admission (§3.3.4), int8 block-paged KV cache (§3.3.3), radix
prefix caching (shared system prompts mapped onto shared KV blocks),
greedy and sampled decoding.  Each measured run's own scheduler trace is
replayed through the analytical twin (``api.forecast(..., trace=...)``),
and the measured-vs-forecast delta is one ``api.compare`` call.

The ``shared system prompt`` mode is the paper's "local agent" traffic:
every request opens with the same 32-token prefix, so warm admissions map
the shared blocks from the radix index and prefill only their suffix —
the measured hit rate and the twin's forecast hit rate come from the same
trace and must agree.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses

from repro import api
from repro.configs.base import Variant

# 6 requests over 3 slots with staggered budgets: slots free mid-flight
# and are reused by the queue (continuous batching, not lockstep)
BASE = api.Scenario(
    model="qwen2-7b", reduced=True, batch=3, prompt_len=64, gen_len=24,
    gen_lens=tuple(24 - 4 * (i % 3) for i in range(6)))

for label, scn in [
    ("baseline bf16-KV", BASE),
    ("chunked admission(16)", dataclasses.replace(BASE, chunk=16)),
    ("int8 KV blocks", dataclasses.replace(
        BASE, variant=Variant(name="bf16-int8kv", kv_dtype="int8",
                              fused=True))),
    ("sampled T=0.8", dataclasses.replace(BASE, temperature=0.8)),
    ("shared system prompt", dataclasses.replace(
        BASE, shared_prefix_len=32, block_size=16, chunk=16)),
]:
    measured = api.measure(scn)
    # same-schedule forecasts: the reduced twin on the paper's CPU spec
    # (apples-to-apples) and the FULL model on the deployment target
    twin_cpu = api.forecast(scn, "cpu", em=0.8, trace=measured.trace)
    twin_v5e = api.forecast(dataclasses.replace(scn, reduced=False),
                            "tpu-v5e", em=0.8, trace=measured.trace)
    delta = api.compare(twin_cpu, measured)
    line = (f"{label:22s} -> {measured.extras['tokens']} toks over "
            f"{measured.extras['requests']} reqs on {scn.batch} slots  "
            f"host {measured.tps:6.1f} tok/s "
            f"(cpu-twin ratio {delta.tps.ratio:5.1f}x)  "
            f"[full model→v5e: {twin_v5e.tps:7.1f} tok/s, "
            f"ttft {twin_v5e.ttft_s*1e3:5.1f}ms, "
            f"tpot {twin_v5e.tpot_s*1e3:5.2f}ms]")
    if scn.shared_prefix_len:
        # measured-vs-forecast hit-rate agreement comes from the shared
        # trace: the engine counted its radix hits, the twin re-derived
        # them from the cached fields of the same events
        line += (f"  [prefix hits: measured "
                 f"{measured.extras['prefix_hit_rate']:.1%} = forecast "
                 f"{twin_v5e.extras['trace_prefix_hit_rate']:.1%}, "
                 f"ttft saved {twin_v5e.extras['trace_ttft_savings_s']*1e3:.1f}ms]")
    print(line)
