"""Serve a small model with batched requests — the paper's optimization
menu live: chunked prefill (§3.3.4), int8 KV cache (§3.3.3), greedy and
sampled decoding; LIFE forecast printed next to host wall-clock.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import Variant
from repro.core import WorkloadModel, Forecaster, hardware
from repro.models import init_params
from repro.runtime import ShardingPolicy, Server, ServeConfig
from repro.launch.mesh import make_host_mesh

ARCH = "qwen2-7b"
BATCH, PROMPT, NEW = 4, 64, 24

full = configs.get(ARCH)
cfg = configs.reduced(full)
mesh = make_host_mesh()
params = init_params(cfg, jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1), (BATCH, PROMPT), 0,
                             cfg.vocab_size, jnp.int32)

# LIFE forecast for the FULL qwen2-7b on the TPU target
wm = WorkloadModel(full, Variant(kv_dtype="int8", fused=True))
fc = Forecaster(hardware.TPU_V5E)
ttft = fc.ttft(wm.prefill(BATCH, PROMPT))
tpot = fc.tpot(wm.decode_step(BATCH, PROMPT), em=0.8)
print(f"[LIFE] {ARCH} on tpu-v5e: TTFT={ttft.latency*1e3:.1f} ms, "
      f"TPOT={tpot*1e3:.2f} ms, TPS={BATCH/tpot:.0f} (batch {BATCH})")

for label, sc in [
    ("baseline bf16-KV", ServeConfig(batch=BATCH, max_len=128)),
    ("chunked prefill(16)", ServeConfig(batch=BATCH, max_len=128,
                                        chunk_size=16)),
    ("int8 KV cache", ServeConfig(batch=BATCH, max_len=128,
                                  kv_dtype="int8")),
    ("sampled T=0.8", ServeConfig(batch=BATCH, max_len=128,
                                  temperature=0.8)),
]:
    with mesh:
        server = Server(cfg, params, mesh, ShardingPolicy(), sc)
        t0 = time.time()
        toks, stats = server.generate(prompts, NEW)
        jax.block_until_ready(toks)
        dt = time.time() - t0
    print(f"{label:22s} -> {toks.shape} tokens in {dt:5.2f}s "
          f"(host {BATCH*NEW/dt:6.1f} tok/s)  first row: "
          f"{list(map(int, toks[0][:6]))}")
