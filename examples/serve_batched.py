"""Serve a stream of requests through the continuous-batching engine —
the paper's optimization menu live: chunked-prefill admission (§3.3.4),
int8 slot-paged KV cache (§3.3.3), greedy and sampled decoding; the LIFE
twin's forecast for the same schedule printed next to host wall-clock.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import Variant
from repro.core import hardware
from repro.engine import Engine, EngineConfig, ForecastTwin, Request
from repro.models import init_params
from repro.runtime import ShardingPolicy
from repro.launch.mesh import make_host_mesh

ARCH = "qwen2-7b"
N_REQ, SLOTS, PROMPT, NEW = 6, 3, 64, 24

full = configs.get(ARCH)
cfg = configs.reduced(full)
mesh = make_host_mesh()
params = init_params(cfg, jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1), (N_REQ, PROMPT), 0,
                             cfg.vocab_size, jnp.int32)


def requests():
    # staggered budgets: slots free mid-flight and are reused by the queue
    return [Request(rid=i, prompt=list(map(int, prompts[i])),
                    max_new=NEW - 4 * (i % 3)) for i in range(N_REQ)]


for label, ec in [
    ("baseline bf16-KV", EngineConfig(max_slots=SLOTS, max_len=128)),
    ("chunked admission(16)", EngineConfig(max_slots=SLOTS, max_len=128,
                                           chunk_size=16)),
    ("int8 KV slots", EngineConfig(max_slots=SLOTS, max_len=128,
                                   kv_dtype="int8")),
    ("sampled T=0.8", EngineConfig(max_slots=SLOTS, max_len=128,
                                   temperature=0.8)),
]:
    with mesh:
        eng = Engine(cfg, params, mesh, ShardingPolicy(), ec)
        eng.warmup()   # compile outside the measured tok/s
        results = eng.run(requests())
    twin = ForecastTwin(full, hardware.TPU_V5E,
                        Variant(kv_dtype=ec.kv_dtype, fused=True), em=0.8)
    fcst = twin.replay(eng.trace)
    done = sum(len(r.tokens) for r in results)
    print(f"{label:22s} -> {done} toks over {len(results)} reqs on "
          f"{ec.max_slots} slots  host {eng.aggregate_tps():6.1f} tok/s  "
          f"[twin→v5e: {fcst.tps:7.1f} tok/s, "
          f"ttft {fcst.mean_ttft*1e3:5.1f}ms, "
          f"tpot {fcst.mean_tpot*1e3:5.2f}ms]  first req: "
          f"{results[0].tokens[:5]}")
