"""Hardware what-if analysis across the whole assigned architecture pool —
LIFE as a deployment-planning tool (paper §5.1.2 generalized):

* per-arch decode TPS on CPU / V100 / TPU v5e at realistic efficiencies
* compute-vs-memory boundary (t_c/t_m) per arch at 4k prefill
* multi-chip scaling: LIFE-distributed forecast of a TP=8 v5e slice

    PYTHONPATH=src python examples/forecast_hardware.py
"""
from repro import configs
from repro.configs.base import Variant
from repro.core import (WorkloadModel, Forecaster, hardware,
                        DistributedForecaster, ShardingPlan)

print(f"{'arch':20s} {'params':>8s} | {'CPU tps':>8s} {'V100 tps':>9s} "
      f"{'v5e tps':>8s} | {'tc/tm @4k prefill':>18s}")
for name in configs.ASSIGNED:
    cfg = configs.get(name)
    wm = WorkloadModel(cfg, Variant(dtype_w="int4", fused=True))
    dec = wm.decode_step(1, 2048)
    pre = wm.prefill(1, 4096)
    row = [f"{name:20s}", f"{cfg.param_count()/1e9:7.1f}B |"]
    for hw, em in ((hardware.RYZEN_9_HX370_CPU, 0.5),
                   (hardware.NVIDIA_V100, 0.5), (hardware.TPU_V5E, 0.8)):
        fc = Forecaster(hw)
        row.append(f"{fc.tps(dec, em=em):8.1f}")
    fc = Forecaster(hardware.TPU_V5E)
    ratio = fc.phase(pre.totals('prefill')).ratio
    row.append(f" | {ratio:17.2f}")
    print(" ".join(row))

print("\nMulti-chip (beyond-paper): llama3-405b decode on a v5e TP slice")
cfg = configs.get("llama3-405b")
wm = WorkloadModel(cfg, Variant(fused=True))
for tp in (8, 16, 32, 64):
    df = DistributedForecaster(wm, ShardingPlan(dp=1, tp=tp))
    t = df.predict_decode(batch=8, past_len=8192)
    tpot = t.bound_time
    print(f"  TP={tp:3d}: tc={t.t_compute*1e3:7.2f}ms tm={t.t_memory*1e3:7.2f}ms "
          f"tx={t.t_collective*1e3:6.2f}ms -> {t.dominant}-bound, "
          f"TPS={8/tpot:7.1f}")
