"""Hardware what-if analysis across the whole assigned architecture pool —
LIFE as a deployment-planning tool (paper §5.1.2 generalized), driven by
the Scenario→Report API:

* per-arch decode TPS on CPU / V100 / TPU v5e at realistic efficiencies
* compute-vs-memory boundary (t_c/t_m) per arch at 4k prefill
* a synthetic TOPS×BW sweep (paper Fig. 5 style) for one workload
* multi-chip scaling: the SAME api.forecast with ``Scenario.tp`` — the
  sharded forecast stack prices per-chip work + collective traffic
  against ``interconnect_GBps`` (no separate distributed forecaster)

    PYTHONPATH=src python examples/forecast_hardware.py
"""
from repro import api, configs
from repro.configs.base import Variant

INT4 = Variant(name="int4-fused", dtype_w="int4", fused=True)

print(f"{'arch':20s} {'params':>8s} | {'CPU tps':>8s} {'V100 tps':>9s} "
      f"{'v5e tps':>8s} | {'TTFT bound @4k':>14s}")
for name in configs.ASSIGNED:
    scn = api.Scenario(model=name, variant=INT4, prompt_len=4096,
                       gen_len=128, past_lens=(2048,))
    row = [f"{name:20s}",
           f"{scn.arch.param_count()/1e9:7.1f}B |"]
    for hw, em in (("cpu", 0.5), ("v100", 0.5), ("v5e", 0.8)):
        row.append(f"{api.forecast(scn, hw, em=em).tps:8.1f}")
    r = api.forecast(scn, "v5e", em=0.8)
    row.append(f" | {r.ttft_bound:>13s}")
    print(" ".join(row))

print("\nTOPS×BW grid (llama2-7b int4, 2k prompt): TPS per synthetic device")
scn = api.Scenario(model="llama2-7b", variant=INT4, prompt_len=2048,
                   gen_len=256)
for r in api.sweep(scn, tops=[10, 50, 200], bw=[100, 400, 1600], em=0.8):
    print(f"  {r.hardware:24s} TTFT={r.ttft_s*1e3:9.1f}ms "
          f"({r.ttft_bound:7s}-bound)  TPS={r.tps:7.1f}")

print("\nMulti-chip (beyond-paper): llama3-405b decode on a v5e TP slice")
for tp in (8, 16, 32, 64):
    scn = api.Scenario(model="llama3-405b", variant=Variant(fused=True),
                       past_lens=(8192,) * 8, gen_len=128, tp=tp)
    r = api.forecast(scn, "v5e", decode_ec=1.0)
    tx = r.extras["decode_collective_s"]
    print(f"  TP={tp:3d}: TPOT={r.tpot_s*1e3:7.2f}ms "
          f"(collective {tx*1e3:5.2f}ms, "
          f"{r.extras['decode_collective_frac']:5.1%}) "
          f"-> {r.tpot_bound}-bound, TPS={r.tps:7.1f}")
