"""End-to-end training driver: ~100M-parameter dense LM, few hundred steps.

Exercises the full substrate on host CPU: synthetic data pipeline ->
sharded train step (remat + grad accumulation) -> AdamW + cosine schedule ->
checkpoint/restart -> loss curve; prints the LIFE forecast of the same
config on TPU v5e first (paper-style: forecast before you burn compute).

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--small]
"""
import argparse
import json
import time

import jax

from repro import configs
from repro.configs.base import Variant
from repro.core import WorkloadModel, Forecaster, hardware
from repro.data import DataConfig, SyntheticTokens
from repro.optim import AdamW
from repro.runtime import ShardingPolicy, Trainer, TrainerConfig
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true",
                    help="~25M params (CI-sized) instead of ~100M")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    base = configs.get("llama2-7b")
    if args.small:
        cfg = configs.reduced(base, d_model=256, n_layers=8, d_ff=1024,
                              n_heads=8, n_kv_heads=8, head_dim=32,
                              vocab_size=32000)
    else:
        cfg = configs.reduced(base, d_model=512, n_layers=12, d_ff=2048,
                              n_heads=8, n_kv_heads=8, head_dim=64,
                              vocab_size=32000)

    # LIFE forecast of a train-like fwd pass on the TPU target
    wm = WorkloadModel(cfg, Variant())
    fc = Forecaster(hardware.TPU_V5E)
    f = fc.phase(wm.prefill(args.batch, args.seq).totals("prefill"))
    print(f"[LIFE→tpu-v5e] fwd/step: tc={f.t_compute*1e3:.2f}ms "
          f"tm={f.t_memory*1e3:.2f}ms ({f.bound}-bound)")

    mesh = make_host_mesh()
    data = SyntheticTokens(cfg, DataConfig(global_batch=args.batch,
                                           seq_len=args.seq, mean_doc_len=96))
    opt = AdamW(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                total_steps=args.steps)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir, log_every=20)
    t0 = time.time()
    with mesh:
        tr = Trainer(cfg, opt, mesh, ShardingPolicy(), data, tc)
        params, _, log = tr.run()
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(json.dumps({"params": n_params, "steps": args.steps,
                      "wall_s": round(time.time() - t0, 1),
                      "loss_curve": [(r["step"], round(r["loss"], 3))
                                     for r in log]}, indent=1))
    assert log[-1]["loss"] < log[0]["loss"], "training did not improve loss"
    print("OK: loss improved", log[0]["loss"], "->", log[-1]["loss"])


if __name__ == "__main__":
    main()
